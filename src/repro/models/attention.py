"""Attention: GQA/MQA, RoPE, qk-norm, sliding windows, chunked-flash, decode.

Three execution paths, all mask-consistent:

* ``attend_full``       — direct einsum softmax (short sequences, smoke tests)
* ``attend_chunked``    — lax.scan over Q and KV blocks with running
                          (max, sum) renormalization — the pure-JAX flash
                          attention used for long prefill so the dry-run never
                          materializes an [S, S] score tensor.  The Pallas TPU
                          kernel (repro.kernels.flash_attention) computes the
                          same thing on-chip; this is its lowering-friendly
                          twin and its oracle.
* ``decode_attend``     — one query token against a static KV cache with a
                          length mask (flash-decoding style when the cache is
                          sharded: XLA turns the masked softmax reductions
                          into partial reductions + all-reduce).

Shapes: x [B, S, d]; caches [B, S_max, H_kv, hd].
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ArchConfig
from repro.models import common
from repro.models import hints

Array = jnp.ndarray
Params = dict[str, Any]

_NEG_INF = -1e30

# Global default for attend_auto's causal block-skip (§Perf-3): opt-in via
# the launcher (--causal-skip) so models need no per-call plumbing.
DEFAULT_CAUSAL_SKIP = False


def init_attention(key, cfg: ArchConfig, dtype) -> Params:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": common.dense_init(ks[0], (d, h * hd), dtype),
        "wk": common.dense_init(ks[1], (d, hkv * hd), dtype),
        "wv": common.dense_init(ks[2], (d, hkv * hd), dtype),
        "wo": common.dense_init(ks[3], (h * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = common.init_rmsnorm(hd, dtype)
        p["k_norm"] = common.init_rmsnorm(hd, dtype)
    return p


def qkv(p: Params, cfg: ArchConfig, x: Array, positions: Array):
    """Project + rope. Returns q [B,S,H,hd], k/v [B,S,Hkv,hd]."""
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = common.rmsnorm(p["q_norm"], q)
        k = common.rmsnorm(p["k_norm"], k)
    if cfg.use_rope:
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _group(q: Array, hkv: int) -> Array:
    """[B,S,H,hd] -> [B,S,Hkv,G,hd] with G = H//Hkv query heads per KV head."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, hkv, h // hkv, hd)


def _mask(
    q_pos: Array, k_pos: Array, window: int | None, causal: bool
) -> Array:
    """[*q, *k] boolean mask; True = attend."""
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return ok


def attend_full(
    q: Array, k: Array, v: Array, *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
) -> Array:
    """Direct softmax attention. q [B,Sq,H,hd]; k,v [B,Sk,Hkv,hd]."""
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    qg = _group(q, hkv)
    scale = hd**-0.5
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    q_pos = jnp.arange(sq) + q_offset
    k_pos = jnp.arange(k.shape[1])
    mask = _mask(q_pos, k_pos, window, causal)
    scores = jnp.where(mask[None, None, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h, v.shape[-1])


def _fit_block(s: int, block: int) -> int:
    """Largest divisor of ``s`` that is <= block (handles e.g. 4352 = 2^8*17)."""
    block = min(block, s)
    while s % block:
        block -= 1
    return block


def attend_chunked(
    q: Array, k: Array, v: Array, *,
    causal: bool = True,
    window: int | None = None,
    q_block: int = 2048,
    kv_block: int = 1024,
    q_offset: Array | int = 0,
) -> Array:
    """Flash-style attention via nested lax.scan over Q and KV blocks.

    Peak live score tensor: [B, Hkv, G, q_block, kv_block].
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    v_dim = v.shape[-1]
    g = h // hkv
    q_block = _fit_block(sq, q_block)
    kv_block = _fit_block(sk, kv_block)
    nq, nk = sq // q_block, sk // kv_block
    scale = hd**-0.5

    qg = _group(q, hkv).reshape(b, nq, q_block, hkv, g, hd).swapaxes(0, 1)
    kb = k.reshape(b, nk, kv_block, hkv, hd).swapaxes(0, 1)
    vb = v.reshape(b, nk, kv_block, hkv, v_dim).swapaxes(0, 1)

    # Pin the model-axis layout of the attention compute: KV heads when they
    # divide the axis, else grouped query heads.  (Head counts that do not
    # divide the model axis go through attend_auto's sequence-parallel
    # shard_map path instead — see below.)  Without a pin, XLA's propagation
    # picks a fragmentary head sharding and replicates most of the compute.
    mesh = hints.active_mesh()
    if mesh is not None:
        choice = hints.pick_divisible(mesh, "model", (3, hkv), (4, g))
        if choice is not None:
            qg = hints.hint(qg, {1: ("pod", "data"), choice: "model"})
            kv_dims = {1: ("pod", "data")}
            if choice == 3:
                kv_dims[3] = "model"
            kb = hints.hint(kb, kv_dims)
            vb = hints.hint(vb, kv_dims)

    def q_step(_, q_blk_idx_and_q):
        qi, qblk = q_blk_idx_and_q  # qi scalar, qblk [B,qb,hkv,g,hd]
        q_pos = q_offset + qi * q_block + jnp.arange(q_block)

        def kv_step(carry, kv):
            m, l, acc = carry
            ki, kblk, vblk = kv
            k_pos = ki * kv_block + jnp.arange(kv_block)
            s_blk = (
                jnp.einsum("bqkgd,bskd->bkgqs", qblk, kblk).astype(jnp.float32)
                * scale
            )
            ok = k_pos[None, :] <= q_pos[:, None] if causal else jnp.ones(
                (q_block, kv_block), bool
            )
            if window is not None:
                ok &= k_pos[None, :] > q_pos[:, None] - window
            s_blk = jnp.where(ok[None, None, None], s_blk, _NEG_INF)
            m_new = jnp.maximum(m, s_blk.max(axis=-1))
            p = jnp.exp(s_blk - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, vblk.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, hkv, g, q_block), _NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, g, q_block), jnp.float32),
            jnp.zeros((b, hkv, g, q_block, v_dim), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init, (jnp.arange(nk), kb, vb)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # [b,hkv,g,qb,hd]
        return None, out.transpose(0, 3, 1, 2, 4)      # [b,qb,hkv,g,hd]

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qg))
    out = outs.swapaxes(0, 1).reshape(b, sq, h, v_dim)
    return out.astype(v.dtype)


def attend_chunked_skip(
    q: Array, k: Array, v: Array, *,
    window: int | None = None,
    q_block: int = 2048,
    kv_block: int = 1024,
) -> Array:
    """Causal flash attention that SKIPS fully-masked KV blocks.

    attend_chunked visits all nq*nk blocks and masks — half the score compute
    of a causal prefill is wasted.  Here the (qi, ki) visit list is built
    statically (ki*kv_block <= end of q block; with a window also
    ki upper-bounded), and a single lax.scan walks it, carrying per-q-block
    running (max, sum, acc) in full-sequence buffers updated in place.
    ~2x fewer score FLOPs for causal, more for windowed (§Perf).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    v_dim = v.shape[-1]
    g = h // hkv
    q_block = _fit_block(sq, q_block)
    kv_block = _fit_block(sk, kv_block)
    nq, nk = sq // q_block, sk // kv_block
    scale = hd**-0.5

    pairs = [
        (qi, ki)
        for qi in range(nq)
        for ki in range(nk)
        if ki * kv_block <= (qi + 1) * q_block - 1
        and (window is None or (ki + 1) * kv_block > qi * q_block - window + 1)
    ]
    qi_arr = jnp.asarray([p_[0] for p_ in pairs], jnp.int32)
    ki_arr = jnp.asarray([p_[1] for p_ in pairs], jnp.int32)

    qg = _group(q, hkv).reshape(b, nq, q_block, hkv, g, hd).swapaxes(0, 1)
    kb = k.reshape(b, nk, kv_block, hkv, hd).swapaxes(0, 1)
    vb = v.reshape(b, nk, kv_block, hkv, v_dim).swapaxes(0, 1)

    mesh = hints.active_mesh()
    if mesh is not None:
        choice = hints.pick_divisible(mesh, "model", (3, hkv), (4, g))
        if choice is not None:
            qg = hints.hint(qg, {1: ("pod", "data"), choice + 1: "model"})
            kv_dims = {1: ("pod", "data")}
            if choice == 3:
                kv_dims[3] = "model"
            kb = hints.hint(kb, kv_dims)
            vb = hints.hint(vb, kv_dims)

    def body(carry, idx):
        m_all, l_all, acc_all = carry
        qi, ki = idx
        qblk = jax.lax.dynamic_index_in_dim(qg, qi, 0, keepdims=False)
        kblk = jax.lax.dynamic_index_in_dim(kb, ki, 0, keepdims=False)
        vblk = jax.lax.dynamic_index_in_dim(vb, ki, 0, keepdims=False)
        q_pos = qi * q_block + jnp.arange(q_block)
        k_pos = ki * kv_block + jnp.arange(kv_block)
        s_blk = (
            jnp.einsum("bqkgd,bskd->bkgqs", qblk, kblk).astype(jnp.float32)
            * scale
        )
        ok = k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            ok &= k_pos[None, :] > q_pos[:, None] - window
        s_blk = jnp.where(ok[None, None, None], s_blk, _NEG_INF)

        m = jax.lax.dynamic_index_in_dim(m_all, qi, 0, keepdims=False)
        l = jax.lax.dynamic_index_in_dim(l_all, qi, 0, keepdims=False)
        acc = jax.lax.dynamic_index_in_dim(acc_all, qi, 0, keepdims=False)
        m_new = jnp.maximum(m, s_blk.max(axis=-1))
        p_ = jnp.exp(s_blk - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p_.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p_, vblk.astype(jnp.float32)
        )
        m_all = jax.lax.dynamic_update_index_in_dim(m_all, m_new, qi, 0)
        l_all = jax.lax.dynamic_update_index_in_dim(l_all, l_new, qi, 0)
        acc_all = jax.lax.dynamic_update_index_in_dim(acc_all, acc_new, qi, 0)
        return (m_all, l_all, acc_all), None

    init = (
        jnp.full((nq, b, hkv, g, q_block), _NEG_INF, jnp.float32),
        jnp.zeros((nq, b, hkv, g, q_block), jnp.float32),
        jnp.zeros((nq, b, hkv, g, q_block, v_dim), jnp.float32),
    )
    (m_all, l_all, acc_all), _ = jax.lax.scan(body, init, (qi_arr, ki_arr))
    out = acc_all / jnp.maximum(l_all, 1e-30)[..., None]   # [nq,b,hkv,g,qb,vd]
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, v_dim)
    return out.astype(v.dtype)


def attend_auto(
    q: Array, k: Array, v: Array, *,
    causal: bool = True,
    window: int | None = None,
    q_block: int = 2048,
    kv_block: int = 1024,
    causal_skip: bool | None = None,
) -> Array:
    """Chunked flash attention with mesh-aware parallelization strategy.

    * heads divide the model axis  -> head-parallel (Megatron layout), via
      the sharding hints inside attend_chunked;
    * otherwise                    -> sequence-parallel: shard_map splits the
      query sequence over the model axis, every shard attends its stripe
      against the (all-gathered) full K/V with a per-stripe position offset.
      This is what keeps e.g. 12-head qwen2 or 6-head whisper from
      replicating score compute 16x (EXPERIMENTS.md §Perf).
    """
    from jax.sharding import PartitionSpec as P

    mesh = hints.active_mesh()
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    if mesh is None:
        return attend_chunked(
            q, k, v, causal=causal, window=window,
            q_block=q_block, kv_block=kv_block,
        )
    if causal_skip is None:
        causal_skip = DEFAULT_CAUSAL_SKIP
    ext = hints.axis_extent(mesh, "model")
    heads_ok = ext and (hkv % ext == 0 or g % ext == 0)
    if heads_ok and causal and causal_skip:
        # Head-parallel + static q positions -> causal block skip applies.
        # Opt-in: ~-20% prefill compute, but the in-place accumulator
        # updates trade HBM traffic for it (EXPERIMENTS.md §Perf).
        return attend_chunked_skip(
            q, k, v, window=window, q_block=q_block, kv_block=kv_block
        )
    if heads_ok or not ext or s % ext or (s // ext) < 16:
        return attend_chunked(
            q, k, v, causal=causal, window=window,
            q_block=q_block, kv_block=kv_block,
        )

    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    s_local = s // ext

    def stripe(qs, ks, vs):
        off = jax.lax.axis_index("model") * s_local
        return attend_chunked(
            qs, ks, vs, causal=causal, window=window,
            q_block=min(q_block, s_local), kv_block=kv_block,
            q_offset=off,
        )

    b_ok = dp_spec is not None and b % hints.axis_extent(mesh, dp) == 0
    bspec = dp_spec if b_ok else None
    return compat.shard_map(
        stripe,
        mesh=mesh,
        in_specs=(
            P(bspec, "model", None, None),
            P(bspec, None, None, None),
            P(bspec, None, None, None),
        ),
        out_specs=P(bspec, "model", None, None),
        check_vma=False,
    )(q, k, v)


def decode_attend(
    q: Array, k_cache: Array, v_cache: Array, pos: Array, *,
    window: int | None = None,
) -> Array:
    """One-step decode. q [B,1,H,hd]; caches [B,S,Hkv,hd]; pos scalar index of
    the current token (cache positions > pos are masked out)."""
    b, _, h, hd = q.shape
    hkv = k_cache.shape[2]
    qg = _group(q, hkv)[:, 0]  # [B,Hkv,G,hd]
    qg = hints.hint(qg, {0: ("pod", "data"), 1: "model"})
    scale = hd**-0.5
    scores = (
        jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32) * scale
    )
    k_pos = jnp.arange(k_cache.shape[1])
    ok = k_pos <= pos
    if window is not None:
        ok &= k_pos > pos - window
    scores = jnp.where(ok[None, None, None, :], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v_cache)
    return out.reshape(b, 1, h, v_cache.shape[-1])


class KVCache(NamedTuple):
    k: Array  # [B, S_max, Hkv, hd]
    v: Array


def update_cache(cache: KVCache, k_new: Array, v_new: Array, pos: Array) -> KVCache:
    """Write one token's k/v at position pos (static cache shape)."""
    k = jax.lax.dynamic_update_slice(cache.k, k_new, (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new, (0, pos, 0, 0))
    return KVCache(k=k, v=v)


def attention_block(
    p: Params,
    cfg: ArchConfig,
    x: Array,
    *,
    positions: Array | None = None,
    window: int | None = None,
    chunked: bool = False,
    cache: KVCache | None = None,
    cache_pos: Array | None = None,
    write_slot: Array | None = None,
):
    """Full attention sub-block (projections + attend + output projection).

    Training/prefill: cache=None -> returns (out, (k, v)).
    Decode: cache given, x is [B, 1, d] -> returns (out, new_cache).
    ``cache_pos`` is the ABSOLUTE token position (RoPE + validity masking);
    ``write_slot`` is the cache slot to write (defaults to cache_pos; ring
    caches pass pos % window).  Ring caches must pass window=None — the ring
    itself enforces the window.
    """
    b, s, _ = x.shape
    if cache is None:
        pos = positions if positions is not None else jnp.arange(s)
        q, k, v = qkv(p, cfg, x, pos)
        attend = attend_auto if chunked else attend_full
        out = attend(q, k, v, causal=True, window=window)
        return out.reshape(b, s, -1) @ p["wo"], (k, v)

    assert cache_pos is not None
    slot = write_slot if write_slot is not None else cache_pos
    pos = jnp.full((1,), cache_pos, jnp.int32)
    q, k, v = qkv(p, cfg, x, pos)
    new_cache = update_cache(cache, k, v, slot)
    out = decode_attend(q, new_cache.k, new_cache.v, cache_pos, window=window)
    return out.reshape(b, s, -1) @ p["wo"], new_cache

"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention
(arXiv:2402.19427) in a 2-recurrent : 1-local-attention pattern.

TPU adaptation: the RG-LRU linear recurrence h_t = a_t h_{t-1} + b_t is
evaluated with ``lax.associative_scan`` (log-depth, VPU-friendly) for
training/prefill, and as an O(1) per-token update for decode.  The layer
pattern is scanned over whole *periods* (rec, rec, attn) so the HLO contains
one period body regardless of depth; remainder layers (38 = 12*3 + 2) are
applied explicitly.

Decode state per period: two (lru_state [B,W], conv tail [B,3,W]) for the
recurrent blocks and a ring KV cache of ``local_window`` for the attention
block — total state is O(window), which is why recurrentgemma runs the
500k-token decode shape natively.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import common
from repro.models import hints

Array = jnp.ndarray
Params = dict[str, Any]

_C = 8.0  # RG-LRU gate exponent constant (Griffin §2.4)


def _pattern(cfg: ArchConfig) -> tuple[str, ...]:
    return cfg.block_pattern or ("rec", "rec", "attn")


def _layout(cfg: ArchConfig) -> tuple[int, tuple[str, ...]]:
    pat = _pattern(cfg)
    n_periods, rem = divmod(cfg.n_layers, len(pat))
    return n_periods, pat[:rem]


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def rg_lru(
    x: Array, r: Array, i: Array, lam: Array, h0: Array | None = None
) -> tuple[Array, Array]:
    """x, r, i: [B, S, W]; lam: [W]. Returns (y [B,S,W], h_last [B,W])."""
    log_a = -_C * r * jax.nn.softplus(-lam)[None, None, :]   # <= 0
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(-jnp.expm1(2.0 * log_a), 1e-12))
    b = mult * (i * x)
    if h0 is not None:
        # Fold the initial state into the first step: h1 = a1 h0 + b1.
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, a_r * b_l + b_r

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def rg_lru_step(
    x: Array, r: Array, i: Array, lam: Array, h_prev: Array
) -> Array:
    """One-token update; all inputs [B, W]."""
    log_a = -_C * r * jax.nn.softplus(-lam)[None, :]
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(-jnp.expm1(2.0 * log_a), 1e-12))
    return a * h_prev + mult * (i * x)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def init_rec_block(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 7)
    return {
        "norm": common.init_rmsnorm(d, dtype),
        "w_x": common.dense_init(ks[0], (d, w), dtype),
        "w_gate": common.dense_init(ks[1], (d, w), dtype),
        "conv_w": common.dense_init(ks[2], (cfg.conv_width, w), dtype, scale=0.5),
        "conv_b": jnp.zeros((w,), dtype),
        "w_r": common.dense_init(ks[3], (w, w), dtype),
        "b_r": jnp.zeros((w,), jnp.float32),
        "w_i": common.dense_init(ks[4], (w, w), dtype),
        "b_i": jnp.zeros((w,), jnp.float32),
        "lam": 4.0 + jnp.zeros((w,), jnp.float32),  # sigmoid(4) ~ .98 slow decay
        "w_out": common.dense_init(ks[5], (w, d), dtype),
        "mlp_norm": common.init_rmsnorm(d, dtype),
        "mlp": common.init_mlp(ks[6], cfg.mlp, d, cfg.d_ff, dtype),
    }


def init_attn_block(key, cfg: ArchConfig, dtype) -> Params:
    k_attn, k_mlp = jax.random.split(key)
    return {
        "norm": common.init_rmsnorm(cfg.d_model, dtype),
        "attn": attn_mod.init_attention(k_attn, cfg, dtype),
        "mlp_norm": common.init_rmsnorm(cfg.d_model, dtype),
        "mlp": common.init_mlp(k_mlp, cfg.mlp, cfg.d_model, cfg.d_ff, dtype),
    }


class RecState(NamedTuple):
    lru: Array    # [B, W]
    conv: Array   # [B, conv_width-1, W]


def _rec_fwd(
    blk: Params, cfg: ArchConfig, h: Array, state: RecState | None = None
):
    """Recurrent block forward. Training (state=None) or decode."""
    xin = common.rmsnorm(blk["norm"], h)
    x = xin @ blk["w_x"]
    gate = jax.nn.gelu(xin @ blk["w_gate"])
    # RG-LRU width over the model axis (4096 / 16) — the recurrence is
    # elementwise over width, so this shards the scan with zero comms.
    x = hints.hint(x, {0: ("pod", "data"), 2: "model"})
    if state is None:
        width = blk["conv_w"].shape[0]
        pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
        x = sum(
            pad[:, i : i + x.shape[1], :] * blk["conv_w"][i][None, None]
            for i in range(width)
        ) + blk["conv_b"]
        r = jax.nn.sigmoid(x @ blk["w_r"] + blk["b_r"])
        i = jax.nn.sigmoid(x @ blk["w_i"] + blk["b_i"])
        y, _ = rg_lru(
            x.astype(jnp.float32), r.astype(jnp.float32), i.astype(jnp.float32),
            blk["lam"],
        )
        y = y.astype(h.dtype) * gate
        out = h + y @ blk["w_out"]
        new_state = None
    else:
        window = jnp.concatenate([state.conv, x], axis=1)        # [B,W,w]
        x1 = jnp.einsum("bwc,wc->bc", window, blk["conv_w"]) + blk["conv_b"]
        r = jax.nn.sigmoid(x1 @ blk["w_r"] + blk["b_r"])
        i = jax.nn.sigmoid(x1 @ blk["w_i"] + blk["b_i"])
        h_new = rg_lru_step(
            x1.astype(jnp.float32), r.astype(jnp.float32), i.astype(jnp.float32),
            blk["lam"], state.lru,
        )
        y = (h_new.astype(h.dtype) * gate[:, 0])[:, None]
        out = h + y @ blk["w_out"]
        new_state = RecState(lru=h_new, conv=window[:, 1:])
    out = out + common.mlp(
        blk["mlp"], cfg.mlp, common.rmsnorm(blk["mlp_norm"], out)
    )
    return out, new_state


def _attn_fwd(
    blk: Params, cfg: ArchConfig, h: Array, *,
    chunked: bool = False,
    cache: attn_mod.KVCache | None = None,
    pos: Array | None = None,
    slot: Array | None = None,
):
    a, new_cache = attn_mod.attention_block(
        blk["attn"], cfg, common.rmsnorm(blk["norm"], h),
        window=cfg.local_window if cache is None else None,
        chunked=chunked, cache=cache, cache_pos=pos, write_slot=slot,
    )
    h = h + a
    h = h + common.mlp(blk["mlp"], cfg.mlp, common.rmsnorm(blk["mlp_norm"], h))
    return h, new_cache


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

def init_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    n_periods, tail = _layout(cfg)
    pat = _pattern(cfg)
    k_emb, k_per, k_tail = jax.random.split(key, 3)

    def init_period(k):
        ks = jax.random.split(k, len(pat))
        return {
            f"b{i}": (
                init_rec_block(ks[i], cfg, dtype)
                if kind == "rec"
                else init_attn_block(ks[i], cfg, dtype)
            )
            for i, kind in enumerate(pat)
        }

    params: Params = {
        "embed": common.init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "periods": jax.vmap(init_period)(jax.random.split(k_per, n_periods)),
        "final_norm": common.init_rmsnorm(cfg.d_model, dtype),
    }
    tail_keys = jax.random.split(k_tail, max(1, len(tail)))
    params["tail"] = [
        init_rec_block(tail_keys[i], cfg, dtype)
        if kind == "rec"
        else init_attn_block(tail_keys[i], cfg, dtype)
        for i, kind in enumerate(tail)
    ]
    return params


def forward(
    params, cfg: ArchConfig, tokens: Array, *,
    chunked_attn: bool = False, remat: bool = True,
) -> Array:
    pat = _pattern(cfg)
    _, tail = _layout(cfg)
    h = common.embed(params["embed"], tokens) * jnp.sqrt(
        jnp.asarray(cfg.d_model, jnp.float32)
    ).astype(params["embed"]["table"].dtype)

    def period_body(h, period):
        for i, kind in enumerate(pat):
            if kind == "rec":
                h, _ = _rec_fwd(period[f"b{i}"], cfg, h)
            else:
                h, _ = _attn_fwd(period[f"b{i}"], cfg, h, chunked=chunked_attn)
        return h, None

    step = jax.checkpoint(period_body) if remat else period_body
    h, _ = jax.lax.scan(step, h, params["periods"])
    for blk, kind in zip(params["tail"], tail, strict=True):
        if kind == "rec":
            h, _ = _rec_fwd(blk, cfg, h)
        else:
            h, _ = _attn_fwd(blk, cfg, h, chunked=chunked_attn)
    return common.rmsnorm(params["final_norm"], h)


def lm_loss(params, cfg: ArchConfig, tokens: Array, *,
            chunked_attn: bool = False, loss_chunk: int = 1024) -> Array:
    h = forward(params, cfg, tokens, chunked_attn=chunked_attn)
    h_in, labels = h[:, :-1], tokens[:, 1:]
    mask = jnp.ones_like(labels, jnp.float32)
    return common.chunked_softmax_xent(
        h_in, labels, mask, params["embed"]["table"],
        chunk=min(loss_chunk, h_in.shape[1]), transpose=True,
    )


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

class RGCache(NamedTuple):
    period_rec: Any     # {bi: RecState stacked [n_periods, ...]} per rec slot
    period_attn: Any    # {bi: KVCache stacked [n_periods, ...]} per attn slot
    tail: tuple         # per tail block: RecState | KVCache


def init_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype) -> RGCache:
    del seq_len
    pat = _pattern(cfg)
    n_periods, tail = _layout(cfg)
    w = cfg.lru_width or cfg.d_model
    win = cfg.local_window

    def rec_state(lead=()):
        return RecState(
            lru=jnp.zeros(lead + (batch, w), jnp.float32),
            conv=jnp.zeros(lead + (batch, cfg.conv_width - 1, w), dtype),
        )

    def kv_cache(lead=()):
        shape = lead + (batch, win, cfg.n_kv_heads, cfg.head_dim)
        return attn_mod.KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))

    period_rec = {
        f"b{i}": rec_state((n_periods,)) for i, k in enumerate(pat) if k == "rec"
    }
    period_attn = {
        f"b{i}": kv_cache((n_periods,)) for i, k in enumerate(pat) if k == "attn"
    }
    tail_states = tuple(
        rec_state() if k == "rec" else kv_cache() for k in tail
    )
    return RGCache(period_rec=period_rec, period_attn=period_attn, tail=tail_states)


def decode_step(
    params, cfg: ArchConfig, cache: RGCache, token: Array, pos: Array
) -> tuple[Array, RGCache]:
    pat = _pattern(cfg)
    _, tail = _layout(cfg)
    h = common.embed(params["embed"], token) * jnp.sqrt(
        jnp.asarray(cfg.d_model, jnp.float32)
    ).astype(params["embed"]["table"].dtype)
    slot = pos % cfg.local_window

    def period_body(h, xs):
        period, rec_states, attn_states = xs
        new_rec, new_attn = {}, {}
        for i, kind in enumerate(pat):
            key = f"b{i}"
            if kind == "rec":
                h, st = _rec_fwd(period[key], cfg, h, state=RecState(*rec_states[key]))
                new_rec[key] = tuple(st)
            else:
                h, c = _attn_fwd(
                    period[key], cfg, h,
                    cache=attn_mod.KVCache(*attn_states[key]), pos=pos, slot=slot,
                )
                new_attn[key] = tuple(c)
        return h, (new_rec, new_attn)

    h, (new_rec, new_attn) = jax.lax.scan(
        period_body,
        h,
        (
            params["periods"],
            {k: tuple(v) for k, v in cache.period_rec.items()},
            {k: tuple(v) for k, v in cache.period_attn.items()},
        ),
    )
    new_rec = {k: RecState(*v) for k, v in new_rec.items()}
    new_attn = {k: attn_mod.KVCache(*v) for k, v in new_attn.items()}

    new_tail = []
    for blk, kind, st in zip(params["tail"], tail, cache.tail, strict=True):
        if kind == "rec":
            h, st_new = _rec_fwd(blk, cfg, h, state=st)
        else:
            h, st_new = _attn_fwd(blk, cfg, h, cache=st, pos=pos, slot=slot)
        new_tail.append(st_new)

    h = common.rmsnorm(params["final_norm"], h)
    logits = h @ params["embed"]["table"].T
    return logits, RGCache(
        period_rec=new_rec, period_attn=new_attn, tail=tuple(new_tail)
    )

"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV state is compressed into a low-rank latent ``c_kv`` [B, S, kv_lora] plus a
shared RoPE key ``k_pe`` [B, S, rope_dim].  Training materializes per-head
k/v from the latent (matmul-heavy — good for the MXU); decoding uses the
*absorbed* form, attending directly in latent space so the cache stays
[S, kv_lora + rope_dim] — the whole point of MLA.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common
from repro.models import hints

Array = jnp.ndarray
Params = dict[str, Any]

_NEG_INF = -1e30


class MLACache(NamedTuple):
    c_kv: Array   # [B, S, kv_lora]
    k_pe: Array   # [B, S, rope_dim]


def init_mla(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    nope, rope, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    p: Params = {
        "w_dkv": common.dense_init(ks[0], (d, cfg.kv_lora_rank), dtype),
        "kv_norm": common.init_rmsnorm(cfg.kv_lora_rank, dtype),
        "w_kpe": common.dense_init(ks[1], (d, rope), dtype),
        "w_uk": common.dense_init(ks[2], (cfg.kv_lora_rank, h * nope), dtype),
        "w_uv": common.dense_init(ks[3], (cfg.kv_lora_rank, h * vdim), dtype),
        "wo": common.dense_init(ks[4], (h * vdim, d), dtype),
    }
    if cfg.q_lora_rank:
        p["w_dq"] = common.dense_init(ks[5], (d, cfg.q_lora_rank), dtype)
        p["q_norm"] = common.init_rmsnorm(cfg.q_lora_rank, dtype)
        p["w_uq"] = common.dense_init(
            ks[6], (cfg.q_lora_rank, h * (nope + rope)), dtype
        )
    else:
        p["w_q"] = common.dense_init(ks[5], (d, h * (nope + rope)), dtype)
    return p


def _queries(p: Params, cfg: ArchConfig, x: Array, positions: Array):
    b, s, _ = x.shape
    h, nope, rope = cfg.n_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        q = common.rmsnorm(p["q_norm"], x @ p["w_dq"]) @ p["w_uq"]
    else:
        q = x @ p["w_q"]
    q = q.reshape(b, s, h, nope + rope)
    q_nope, q_pe = q[..., :nope], q[..., nope:]
    q_pe = common.apply_rope(q_pe, positions, cfg.rope_theta)
    return q_nope, q_pe


def _latents(p: Params, cfg: ArchConfig, x: Array, positions: Array):
    c_kv = common.rmsnorm(p["kv_norm"], x @ p["w_dkv"])        # [B,S,r]
    k_pe = x @ p["w_kpe"]                                      # [B,S,rope]
    k_pe = common.apply_rope(k_pe[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_pe


def mla_block(
    p: Params,
    cfg: ArchConfig,
    x: Array,
    *,
    chunked: bool = False,
    cache: MLACache | None = None,
    cache_pos: Array | None = None,
    write_slot: Array | None = None,
):
    """Training/prefill (cache=None) or one-step decode (cache given)."""
    b, s, _ = x.shape
    h = cfg.n_heads
    nope, rope, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    scale = (nope + rope) ** -0.5

    if cache is None:
        positions = jnp.arange(s)
        q_nope, q_pe = _queries(p, cfg, x, positions)
        c_kv, k_pe = _latents(p, cfg, x, positions)
        k_nope = (c_kv @ p["w_uk"]).reshape(b, s, h, nope)
        v = (c_kv @ p["w_uv"]).reshape(b, s, h, vdim)
        q_nope = hints.hint(q_nope, {0: ("pod", "data"), 2: "model"})
        k_nope = hints.hint(k_nope, {0: ("pod", "data"), 2: "model"})
        v = hints.hint(v, {0: ("pod", "data"), 2: "model"})

        if chunked:
            # Long prefill: reuse the flash-style block scan.  Fold the shared
            # RoPE key into a per-head key (concat) so the generic kernel
            # applies; scaling is handled by attend_*'s 1/sqrt(head_dim) with
            # head_dim = nope + rope, which matches MLA's scale.
            from repro.models import attention as attn_mod

            q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
            k_full = jnp.concatenate(
                [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (b, s, h, rope))],
                axis=-1,
            )
            out = attn_mod.attend_auto(q_full, k_full, v, causal=True)
        else:
            scores = (
                jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
                + jnp.einsum("bqhd,bkd->bhqk", q_pe, k_pe)
            ).astype(jnp.float32) * scale
            causal = jnp.tril(jnp.ones((s, s), bool))
            scores = jnp.where(causal[None, None], scores, _NEG_INF)
            probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
            out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
        return out.reshape(b, s, h * vdim) @ p["wo"], (c_kv, k_pe)

    # ---- absorbed decode: attend in latent space ----
    assert cache_pos is not None and s == 1
    slot = write_slot if write_slot is not None else cache_pos
    positions = jnp.full((1,), cache_pos, jnp.int32)
    q_nope, q_pe = _queries(p, cfg, x, positions)             # [B,1,h,*]
    c_new, kpe_new = _latents(p, cfg, x, positions)           # [B,1,r], [B,1,rope]
    c_kv = jax.lax.dynamic_update_slice(cache.c_kv, c_new, (0, slot, 0))
    k_pe = jax.lax.dynamic_update_slice(cache.k_pe, kpe_new, (0, slot, 0))

    w_uk = p["w_uk"].reshape(cfg.kv_lora_rank, h, nope)
    q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)[:, 0]  # [B,h,r]
    scores = (
        jnp.einsum("bhr,bsr->bhs", q_abs, c_kv)
        + jnp.einsum("bhd,bsd->bhs", q_pe[:, 0], k_pe)
    ).astype(jnp.float32) * scale
    k_idx = jnp.arange(c_kv.shape[1])
    scores = jnp.where((k_idx <= cache_pos)[None, None, :], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(c_kv.dtype)
    ctx = jnp.einsum("bhs,bsr->bhr", probs, c_kv)             # [B,h,r]
    w_uv = p["w_uv"].reshape(cfg.kv_lora_rank, h, vdim)
    out = jnp.einsum("bhr,rhd->bhd", ctx, w_uv).reshape(b, 1, h * vdim)
    return out @ p["wo"], MLACache(c_kv=c_kv, k_pe=k_pe)

"""ModelBundle — the uniform interface the launcher/dry-run drives.

Per family it wires up:
  init(key, dtype)                 -> params
  loss(params, batch)              -> scalar (training objective)
  prefill(params, batch)           -> last-token logits  (serve prefill)
  init_cache(batch, seq, dtype)    -> decode cache pytree (zeros; the dry-run
                                      replaces it with ShapeDtypeStructs)
  decode(params, cache, token, pos)-> (logits, cache)    (serve decode step)
  input_specs(shape, dtype)        -> {name: ShapeDtypeStruct} for the shape

``batch`` dicts by family:
  dense/moe/ssm/hybrid : {tokens [B,S]}
  vlm                  : {tokens [B,S], patch_embeds [B,P,d_frontend]}
  encdec               : {tokens [B,S], frames [B,T_enc,d_model]}
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.registry import InputShape
from repro.models import encdec, mamba2, moe_lm, rglru, transformer, vlm

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ArchConfig
    init: Callable[..., Any]
    loss: Callable[..., Array]
    prefill: Callable[..., Array]
    init_cache: Callable[..., Any]
    decode: Callable[..., tuple[Array, Any]]
    input_specs: Callable[..., dict[str, Any]]


def _token_specs(shape: InputShape, dtype=jnp.int32) -> dict[str, Any]:
    return {
        "tokens": jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len), dtype)
    }


def get_bundle(cfg: ArchConfig, *, chunked_attn: bool = True) -> ModelBundle:
    fam = cfg.family
    long_seq = chunked_attn  # chunk the attention for long prefill shapes

    if fam in ("dense",):
        mod = transformer

        def loss(params, batch):
            return mod.lm_loss(params, cfg, batch["tokens"], chunked_attn=long_seq)

        def prefill(params, batch):
            h = mod.forward(
                params, cfg, batch["tokens"], chunked_attn=long_seq, remat=False
            )
            w = params["embed"]["table"] if cfg.tie_embeddings else params["lm_head"]
            return (h[:, -1:] @ (w.T if cfg.tie_embeddings else w))

        def init_cache(batch_size, seq_len, dtype):
            return mod.init_cache(cfg, batch_size, seq_len, dtype)

        def decode(params, cache, token, pos):
            return mod.decode_step(params, cfg, cache, token, pos)

        def input_specs(shape: InputShape, dtype=jnp.float32):
            return _token_specs(shape)

        return ModelBundle(
            cfg=cfg,
            init=lambda key, dtype=jnp.float32: mod.init_params(key, cfg, dtype),
            loss=loss,
            prefill=prefill,
            init_cache=init_cache,
            decode=decode,
            input_specs=input_specs,
        )

    if fam == "vlm":
        def loss(params, batch):
            return vlm.lm_loss(
                params, cfg, batch["patch_embeds"], batch["tokens"],
                chunked_attn=long_seq,
            )

        def prefill(params, batch):
            prefix = vlm.project(params, batch["patch_embeds"])
            h = transformer.forward(
                params, cfg, batch["tokens"], prefix_embeds=prefix,
                chunked_attn=long_seq, remat=False,
            )
            return h[:, -1:] @ params["lm_head"]

        def init_cache(batch_size, seq_len, dtype):
            return transformer.init_cache(cfg, batch_size, seq_len, dtype)

        def decode(params, cache, token, pos):
            return transformer.decode_step(params, cfg, cache, token, pos)

        def input_specs(shape: InputShape, dtype=jnp.float32):
            specs = _token_specs(shape)
            if shape.kind != "decode":
                specs["patch_embeds"] = jax.ShapeDtypeStruct(
                    (shape.global_batch, cfg.n_patches, cfg.d_frontend), dtype
                )
            return specs

        return ModelBundle(
            cfg=cfg,
            init=lambda key, dtype=jnp.float32: vlm.init_params(key, cfg, dtype),
            loss=loss,
            prefill=prefill,
            init_cache=init_cache,
            decode=decode,
            input_specs=input_specs,
        )

    if fam == "moe":
        def loss(params, batch):
            return moe_lm.lm_loss(params, cfg, batch["tokens"], chunked_attn=long_seq)

        def prefill(params, batch):
            h, _ = moe_lm.forward(
                params, cfg, batch["tokens"], chunked_attn=long_seq, remat=False
            )
            w = params["embed"]["table"] if cfg.tie_embeddings else params["lm_head"]
            return h[:, -1:] @ (w.T if cfg.tie_embeddings else w)

        def init_cache(batch_size, seq_len, dtype):
            return moe_lm.init_cache(cfg, batch_size, seq_len, dtype)

        def decode(params, cache, token, pos):
            return moe_lm.decode_step(params, cfg, cache, token, pos)

        def input_specs(shape: InputShape, dtype=jnp.float32):
            return _token_specs(shape)

        return ModelBundle(
            cfg=cfg,
            init=lambda key, dtype=jnp.float32: moe_lm.init_params(key, cfg, dtype),
            loss=loss,
            prefill=prefill,
            init_cache=init_cache,
            decode=decode,
            input_specs=input_specs,
        )

    if fam == "ssm":
        def loss(params, batch):
            return mamba2.lm_loss(params, cfg, batch["tokens"])

        def prefill(params, batch):
            h = mamba2.forward(params, cfg, batch["tokens"], remat=False)
            return h[:, -1:] @ params["embed"]["table"].T

        def init_cache(batch_size, seq_len, dtype):
            return mamba2.init_cache(cfg, batch_size, seq_len, dtype)

        def decode(params, cache, token, pos):
            return mamba2.decode_step(params, cfg, cache, token, pos)

        def input_specs(shape: InputShape, dtype=jnp.float32):
            return _token_specs(shape)

        return ModelBundle(
            cfg=cfg,
            init=lambda key, dtype=jnp.float32: mamba2.init_params(key, cfg, dtype),
            loss=loss,
            prefill=prefill,
            init_cache=init_cache,
            decode=decode,
            input_specs=input_specs,
        )

    if fam == "hybrid":
        def loss(params, batch):
            return rglru.lm_loss(params, cfg, batch["tokens"], chunked_attn=long_seq)

        def prefill(params, batch):
            h = rglru.forward(
                params, cfg, batch["tokens"], chunked_attn=long_seq, remat=False
            )
            return h[:, -1:] @ params["embed"]["table"].T

        def init_cache(batch_size, seq_len, dtype):
            return rglru.init_cache(cfg, batch_size, seq_len, dtype)

        def decode(params, cache, token, pos):
            return rglru.decode_step(params, cfg, cache, token, pos)

        def input_specs(shape: InputShape, dtype=jnp.float32):
            return _token_specs(shape)

        return ModelBundle(
            cfg=cfg,
            init=lambda key, dtype=jnp.float32: rglru.init_params(key, cfg, dtype),
            loss=loss,
            prefill=prefill,
            init_cache=init_cache,
            decode=decode,
            input_specs=input_specs,
        )

    if fam == "encdec":
        def loss(params, batch):
            return encdec.lm_loss(params, cfg, batch["frames"], batch["tokens"])

        def prefill(params, batch):
            enc_out = encdec.encode(params, cfg, batch["frames"])
            h = encdec.decode_train(params, cfg, enc_out, batch["tokens"])
            return h[:, -1:] @ params["embed"]["table"].T

        def init_cache(batch_size, seq_len, dtype):
            # Encoder output is part of the decode-state (cross-KV); zeros here,
            # ShapeDtypeStructs in the dry-run.
            enc_out = jnp.zeros((batch_size, cfg.encoder_seq, cfg.d_model), dtype)
            params = None  # cross_kv needs params; see api.init_cache_with_params
            raise NotImplementedError(
                "enc-dec cache needs params; use encdec_cache_specs / "
                "encdec.init_cache directly"
            )

        def decode(params, cache, token, pos):
            return encdec.decode_step(params, cfg, cache, token, pos)

        def input_specs(shape: InputShape, dtype=jnp.float32):
            specs = _token_specs(shape)
            if shape.kind != "decode":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (shape.global_batch, cfg.encoder_seq, cfg.d_model), dtype
                )
            return specs

        return ModelBundle(
            cfg=cfg,
            init=lambda key, dtype=jnp.float32: encdec.init_params(key, cfg, dtype),
            loss=loss,
            prefill=prefill,
            init_cache=init_cache,
            decode=decode,
            input_specs=input_specs,
        )

    raise ValueError(f"unknown family {fam!r}")


def cache_specs(
    bundle: ModelBundle, batch: int, seq_len: int, dtype
) -> Any:
    """ShapeDtypeStruct pytree for the decode cache (no allocation)."""
    cfg = bundle.cfg
    if cfg.family == "encdec":
        from repro.models import attention as attn_mod

        shape = (cfg.n_layers, batch, seq_len, cfg.n_kv_heads, cfg.head_dim)
        xshape = (cfg.n_layers, batch, cfg.encoder_seq, cfg.n_heads, cfg.head_dim)
        sd = lambda s: jax.ShapeDtypeStruct(s, dtype)
        return encdec.EncDecCache(
            self_kv=attn_mod.KVCache(k=sd(shape), v=sd(shape)),
            cross_kv=(sd(xshape), sd(xshape)),
        )
    return jax.eval_shape(
        lambda: bundle.init_cache(batch, seq_len, dtype)
    )

"""Architecture zoo: six families behind one ModelBundle interface."""
from repro.models.api import ModelBundle, cache_specs, get_bundle  # noqa: F401

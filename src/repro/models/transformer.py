"""Dense decoder-only transformer (llama-lineage) with scan-over-layers.

Covers the assigned dense architectures (mistral-nemo-12b, granite-20b,
qwen3-1.7b, qwen2-1.5b) and, with a patch-embedding prefix, the InternVL2 VLM
decoder (models/vlm.py).

Layer parameters are stacked along a leading [L] axis and the stack is
traversed with ``lax.scan`` + ``jax.checkpoint`` — this keeps the HLO compact
(one layer body regardless of depth), makes remat policy explicit, and is
what lets 40-60-layer configs compile quickly in the multi-pod dry-run.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import common

Array = jnp.ndarray
Params = dict[str, Any]


def init_layer(key, cfg: ArchConfig, dtype) -> Params:
    k_attn, k_mlp = jax.random.split(key)
    return {
        "attn_norm": common.init_norm(cfg.norm, cfg.d_model, dtype),
        "attn": attn_mod.init_attention(k_attn, cfg, dtype),
        "mlp_norm": common.init_norm(cfg.norm, cfg.d_model, dtype),
        "mlp": common.init_mlp(k_mlp, cfg.mlp, cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer(k, cfg, dtype))(layer_keys)
    params: Params = {
        "embed": common.init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "layers": layers,
        "final_norm": common.init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = common.dense_init(
            k_head, (cfg.d_model, cfg.vocab_size), dtype
        )
    return params


def _layer_fwd(cfg: ArchConfig, window, chunked):
    def body(h: Array, layer: Params) -> Array:
        a, _ = attn_mod.attention_block(
            layer["attn"],
            cfg,
            common.apply_norm(cfg.norm, layer["attn_norm"], h),
            window=window,
            chunked=chunked,
        )
        h = h + a
        m = common.mlp(
            layer["mlp"], cfg.mlp, common.apply_norm(cfg.norm, layer["mlp_norm"], h)
        )
        return h + m

    return body


def forward(
    params: Params,
    cfg: ArchConfig,
    tokens: Array,
    *,
    prefix_embeds: Array | None = None,
    chunked_attn: bool = False,
    window: int | None = None,
    remat: bool = True,
) -> Array:
    """Hidden states [B, S(+P), d] for training/prefill."""
    h = common.embed(params["embed"], tokens)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    win = window if window is not None else cfg.sliding_window

    body = _layer_fwd(cfg, win, chunked_attn)
    step = jax.checkpoint(lambda h, lp: (body(h, lp), None)) if remat else (
        lambda h, lp: (body(h, lp), None)
    )
    h, _ = jax.lax.scan(step, h, params["layers"])
    return common.apply_norm(cfg.norm, params["final_norm"], h)


def lm_loss(
    params: Params,
    cfg: ArchConfig,
    tokens: Array,
    *,
    prefix_embeds: Array | None = None,
    chunked_attn: bool = False,
    loss_chunk: int = 1024,
) -> Array:
    """Next-token cross-entropy; prefix (image) positions carry no loss."""
    h = forward(
        params, cfg, tokens, prefix_embeds=prefix_embeds, chunked_attn=chunked_attn
    )
    n_prefix = 0 if prefix_embeds is None else prefix_embeds.shape[1]
    h = h[:, n_prefix:]
    h_in, labels = h[:, :-1], tokens[:, 1:]
    mask = jnp.ones_like(labels, jnp.float32)
    w = params["embed"]["table"] if cfg.tie_embeddings else params["lm_head"]
    return common.chunked_softmax_xent(
        h_in, labels, mask, w,
        chunk=min(loss_chunk, h_in.shape[1]),
        transpose=cfg.tie_embeddings,
    )


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype) -> attn_mod.KVCache:
    """Stacked [L, B, S, Hkv, hd] KV cache (sliding-window archs allocate only
    the window)."""
    s = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    shape = (cfg.n_layers, batch, s, cfg.n_kv_heads, cfg.head_dim)
    return attn_mod.KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def decode_step(
    params: Params,
    cfg: ArchConfig,
    cache: attn_mod.KVCache,
    token: Array,       # [B, 1]
    pos: Array,         # scalar int32 — position of this token
) -> tuple[Array, attn_mod.KVCache]:
    """One decoding step against a static cache; scan over layers."""
    h = common.embed(params["embed"], token)
    window = cfg.sliding_window
    cache_len = cache.k.shape[2]
    # With a ring (windowed) cache the write slot wraps around.
    slot = pos % cache_len if window else pos

    def body(h, xs):
        layer, kc, vc = xs
        a, new_c = attn_mod.attention_block(
            layer["attn"],
            cfg,
            common.apply_norm(cfg.norm, layer["attn_norm"], h),
            cache=attn_mod.KVCache(kc, vc),
            cache_pos=pos,
            write_slot=slot,
        )
        h = h + a
        h = h + common.mlp(
            layer["mlp"], cfg.mlp, common.apply_norm(cfg.norm, layer["mlp_norm"], h)
        )
        return h, (new_c.k, new_c.v)

    h, (ks, vs) = jax.lax.scan(body, h, (params["layers"], cache.k, cache.v))
    h = common.apply_norm(cfg.norm, params["final_norm"], h)
    w = params["embed"]["table"] if cfg.tie_embeddings else params["lm_head"]
    logits = common.logits_from_hidden(
        h, params["embed"], None if cfg.tie_embeddings else w
    )
    return logits, attn_mod.KVCache(k=ks, v=vs)

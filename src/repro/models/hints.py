"""Sharding hints: mesh-aware ``with_sharding_constraint`` that no-ops when
no mesh is active.

Models stay mesh-agnostic (smoke tests run un-sharded on one CPU device), but
under ``jax.set_mesh`` (the launcher/dry-run) these hints pin the layouts the
2D (data, model) strategy intends — most importantly inside attention, where
XLA's propagation otherwise picks a fragmentary head sharding for head counts
that do not divide the model axis (DESIGN.md §5, EXPERIMENTS.md §Perf).

``hint(x, {dim: axis})`` applies an axis to a dim only when the dim size is
divisible by the mesh extent of that axis; everything else is left to the
propagator (PartitionSpec.UNCONSTRAINED on unmentioned dims would be too
strict — None lets XLA refine).
"""
from __future__ import annotations

import math

import jax
from jax.sharding import PartitionSpec as P

from repro import compat

Axis = str | tuple[str, ...]


def active_mesh():
    mesh = compat.get_abstract_mesh()
    if mesh is None or not tuple(getattr(mesh, "axis_names", ())):
        return None
    return mesh


def axis_extent(mesh, axis: Axis) -> int:
    names = (axis,) if isinstance(axis, str) else axis
    sizes = dict(mesh.shape)
    return math.prod(sizes.get(n, 0) or 0 for n in names) or 0


def hint(x, dims: dict[int, Axis]):
    """Constrain ``x`` so dim ``d`` is sharded over ``dims[d]`` when divisible."""
    mesh = active_mesh()
    if mesh is None:
        return x
    spec: list = [None] * x.ndim
    used: set = set()
    for d, axis in dims.items():
        names = (axis,) if isinstance(axis, str) else tuple(axis)
        names = tuple(n for n in names if n in mesh.axis_names and n not in used)
        if not names:
            continue
        ext = axis_extent(mesh, names)
        if ext and x.shape[d] % ext == 0:
            spec[d] = names if len(names) > 1 else names[0]
            used.update(names)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def pick_divisible(mesh, axis: str, *candidates: tuple[int, int]) -> int | None:
    """First candidate (dim_index, dim_size) divisible by the axis extent."""
    ext = axis_extent(mesh, axis)
    if not ext:
        return None
    for idx, size in candidates:
        if size % ext == 0:
            return idx
    return None

"""Whisper-style encoder-decoder transformer (arXiv:2212.04356).

Per the assignment spec, the mel-spectrogram + conv feature extractor is a
STUB: ``input_specs`` provides precomputed frame embeddings [B, T_enc, d]
(what Whisper's two conv layers would emit).  This module implements the
transformer backbone: a bidirectional encoder over frames and a causal
decoder with cross-attention — pre-LayerNorm, GELU MLPs, learned/sinusoidal
positions, biasless K (as in Whisper), tied decoder embedding.

Whisper-tiny uses full (quadratic) attention with a 448-token decoder
context; long_500k is skipped for this arch (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import common

Array = jnp.ndarray
Params = dict[str, Any]

_NEG_INF = -1e30


def _init_xattn(key, cfg: ArchConfig, dtype) -> Params:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": common.dense_init(ks[0], (d, h * hd), dtype),
        "wk": common.dense_init(ks[1], (d, h * hd), dtype),
        "wv": common.dense_init(ks[2], (d, h * hd), dtype),
        "wo": common.dense_init(ks[3], (h * hd, d), dtype),
    }


def _xattn(p: Params, cfg: ArchConfig, x: Array, kv: tuple[Array, Array]) -> Array:
    """Cross attention: x [B,Sq,d] against precomputed (k, v) [B,Se,H,hd]."""
    b, sq, _ = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, sq, h, hd)
    k, v = kv
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32) * hd**-0.5
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v).reshape(b, sq, h * hd)
    return out @ p["wo"]


def xattn_kv(p: Params, cfg: ArchConfig, enc_out: Array) -> tuple[Array, Array]:
    b, se, _ = enc_out.shape
    h, hd = cfg.n_heads, cfg.head_dim
    k = (enc_out @ p["wk"]).reshape(b, se, h, hd)
    v = (enc_out @ p["wv"]).reshape(b, se, h, hd)
    return k, v


def _init_enc_layer(key, cfg: ArchConfig, dtype) -> Params:
    k_attn, k_mlp = jax.random.split(key)
    return {
        "attn_norm": common.init_layernorm(cfg.d_model, dtype),
        "attn": attn_mod.init_attention(key=k_attn, cfg=cfg, dtype=dtype),
        "mlp_norm": common.init_layernorm(cfg.d_model, dtype),
        "mlp": common.init_mlp(k_mlp, "gelu_mlp", cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_layer(key, cfg: ArchConfig, dtype) -> Params:
    k_self, k_cross, k_mlp = jax.random.split(key, 3)
    return {
        "self_norm": common.init_layernorm(cfg.d_model, dtype),
        "self_attn": attn_mod.init_attention(k_self, cfg, dtype),
        "cross_norm": common.init_layernorm(cfg.d_model, dtype),
        "cross_attn": _init_xattn(k_cross, cfg, dtype),
        "mlp_norm": common.init_layernorm(cfg.d_model, dtype),
        "mlp": common.init_mlp(k_mlp, "gelu_mlp", cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    k_enc, k_dec, k_emb = jax.random.split(key, 3)
    enc_layers = jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(
        jax.random.split(k_enc, cfg.n_encoder_layers)
    )
    dec_layers = jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(
        jax.random.split(k_dec, cfg.n_layers)
    )
    return {
        "embed": common.init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "enc_layers": enc_layers,
        "enc_norm": common.init_layernorm(cfg.d_model, dtype),
        "dec_layers": dec_layers,
        "dec_norm": common.init_layernorm(cfg.d_model, dtype),
        "dec_pos": common.embed_init(
            jax.random.PRNGKey(7), (cfg.max_seq_len, cfg.d_model), dtype
        ),
    }


def encode(params: Params, cfg: ArchConfig, frames: Array) -> Array:
    """frames [B, T_enc, d] (conv-stub output) -> encoder states."""
    h = frames + common.sinusoidal_positions(frames.shape[1], cfg.d_model).astype(
        frames.dtype
    )

    def body(h, layer):
        x = common.layernorm(layer["attn_norm"], h)
        pos = jnp.arange(h.shape[1])
        q, k, v = attn_mod.qkv(layer["attn"], cfg, x, pos)
        a = attn_mod.attend_full(q, k, v, causal=False)
        h = h + a.reshape(h.shape[0], h.shape[1], -1) @ layer["attn"]["wo"]
        m = common.mlp(layer["mlp"], "gelu_mlp", common.layernorm(layer["mlp_norm"], h))
        return h + m, None

    step = jax.checkpoint(body)
    h, _ = jax.lax.scan(step, h, params["enc_layers"])
    return common.layernorm(params["enc_norm"], h)


def decode_train(
    params: Params, cfg: ArchConfig, enc_out: Array, tokens: Array,
    *, remat: bool = True,
) -> Array:
    """Teacher-forced decoder hidden states [B, S, d]."""
    s = tokens.shape[1]
    h = common.embed(params["embed"], tokens) + params["dec_pos"][:s][None]
    chunked = s > 2048

    def body(h, layer):
        a, _ = attn_mod.attention_block(
            layer["self_attn"], cfg, common.layernorm(layer["self_norm"], h),
            chunked=chunked,
        )
        h = h + a
        kv = xattn_kv(layer["cross_attn"], cfg, enc_out)
        h = h + _xattn(
            layer["cross_attn"], cfg, common.layernorm(layer["cross_norm"], h), kv
        )
        m = common.mlp(layer["mlp"], "gelu_mlp", common.layernorm(layer["mlp_norm"], h))
        return h + m, None

    step = jax.checkpoint(body) if remat else body
    h, _ = jax.lax.scan(step, h, params["dec_layers"])
    return common.layernorm(params["dec_norm"], h)


def lm_loss(
    params: Params, cfg: ArchConfig, frames: Array, tokens: Array
) -> Array:
    enc_out = encode(params, cfg, frames)
    h = decode_train(params, cfg, enc_out, tokens)
    h_in, labels = h[:, :-1], tokens[:, 1:]
    mask = jnp.ones_like(labels, jnp.float32)
    return common.chunked_softmax_xent(
        h_in, labels, mask, params["embed"]["table"],
        chunk=min(512, h_in.shape[1]), transpose=True,
    )


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

class EncDecCache(NamedTuple):
    self_kv: attn_mod.KVCache   # [L, B, S_max, H, hd]
    cross_kv: tuple             # (k, v) [L, B, T_enc, H, hd] — fixed after prefill


def init_cache(
    params: Params, cfg: ArchConfig, enc_out: Array, seq_len: int, dtype
) -> EncDecCache:
    b = enc_out.shape[0]
    shape = (cfg.n_layers, b, seq_len, cfg.n_kv_heads, cfg.head_dim)
    # Cross K/V computed once per request (the "prefill" of an enc-dec model).
    def per_layer(layer):
        return xattn_kv(layer["cross_attn"], cfg, enc_out)

    ks, vs = jax.vmap(per_layer, in_axes=(0,))(params["dec_layers"])
    return EncDecCache(
        self_kv=attn_mod.KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype)),
        cross_kv=(ks, vs),
    )


def decode_step(
    params: Params, cfg: ArchConfig, cache: EncDecCache, token: Array, pos: Array
) -> tuple[Array, EncDecCache]:
    h = common.embed(params["embed"], token) + jax.lax.dynamic_slice_in_dim(
        params["dec_pos"], pos, 1, axis=0
    )[None]

    def body(h, xs):
        layer, kc, vc, xk, xv = xs
        a, new_c = attn_mod.attention_block(
            layer["self_attn"], cfg, common.layernorm(layer["self_norm"], h),
            cache=attn_mod.KVCache(kc, vc), cache_pos=pos,
        )
        h = h + a
        h = h + _xattn(
            layer["cross_attn"], cfg, common.layernorm(layer["cross_norm"], h),
            (xk, xv),
        )
        m = common.mlp(layer["mlp"], "gelu_mlp", common.layernorm(layer["mlp_norm"], h))
        return h + m, (new_c.k, new_c.v)

    xk, xv = cache.cross_kv
    h, (ks, vs) = jax.lax.scan(
        body, h, (params["dec_layers"], cache.self_kv.k, cache.self_kv.v, xk, xv)
    )
    h = common.layernorm(params["dec_norm"], h)
    logits = h @ params["embed"]["table"].T
    return logits, EncDecCache(
        self_kv=attn_mod.KVCache(k=ks, v=vs), cross_kv=cache.cross_kv
    )

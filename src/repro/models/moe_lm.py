"""MoE language models: qwen2-moe (GQA attention) and deepseek-v2 (MLA).

Same scan-over-layers skeleton as models/transformer.py, with:
  * MoE FFN (models/moe.py) + router load-balance aux loss threaded through
    the scan carry;
  * optional ``first_dense_layers`` whose FFN is a dense SwiGLU of width
    ``d_ff_dense`` (DeepSeek-V2 layer 0) — kept as a separately-stacked scan;
  * MLA attention + latent cache when ``cfg.mla``.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

import os

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import common, hints, mla, moe


def _seq_shard() -> bool:
    """§Perf experiment (env-gated; defaults unchanged): shard the residual
    stream's sequence dim over the model axis between blocks (Megatron-SP
    style) — norms/router/expert math are pointwise over S, attention
    gathers.  Resolved at call time (the ``stats_backend.resolved()``
    idiom), never at import, so tests/serving can flip it per-process;
    callers that jit the forward pass bake the resolved value into that
    trace and pass ``seq_shard=`` explicitly to override per-call."""
    return os.environ.get("REPRO_SEQ_SHARD", "0") == "1"

Array = jnp.ndarray
Params = dict[str, Any]


class MoECaches(NamedTuple):
    """Decode caches for the dense-prefix layers and the MoE layers."""

    dense: Any   # KVCache | MLACache stacked [L_dense, ...] or None
    moe: Any     # KVCache | MLACache stacked [L_moe, ...]


def _init_attn(key, cfg: ArchConfig, dtype):
    return mla.init_mla(key, cfg, dtype) if cfg.mla else attn_mod.init_attention(
        key, cfg, dtype
    )


def _init_layer(key, cfg: ArchConfig, dtype, dense_ffn: bool) -> Params:
    k_attn, k_ffn = jax.random.split(key)
    p = {
        "attn_norm": common.init_norm(cfg.norm, cfg.d_model, dtype),
        "attn": _init_attn(k_attn, cfg, dtype),
        "mlp_norm": common.init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if dense_ffn:
        p["mlp"] = common.init_mlp(
            k_ffn, "swiglu", cfg.d_model, cfg.d_ff_dense or cfg.d_ff, dtype
        )
    else:
        p["moe"] = moe.init_moe_ffn(k_ffn, cfg, dtype)
    return p


def init_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    k_emb, k_dense, k_moe, k_head = jax.random.split(key, 4)
    n_dense = cfg.first_dense_layers
    n_moe = cfg.n_layers - n_dense
    params: Params = {
        "embed": common.init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": common.init_norm(cfg.norm, cfg.d_model, dtype),
        "moe_layers": jax.vmap(lambda k: _init_layer(k, cfg, dtype, False))(
            jax.random.split(k_moe, n_moe)
        ),
    }
    if n_dense:
        params["dense_layers"] = jax.vmap(lambda k: _init_layer(k, cfg, dtype, True))(
            jax.random.split(k_dense, n_dense)
        )
    if not cfg.tie_embeddings:
        params["lm_head"] = common.dense_init(
            k_head, (cfg.d_model, cfg.vocab_size), dtype
        )
    return params


def _attn_fwd(layer: Params, cfg: ArchConfig, h: Array, chunked: bool) -> Array:
    x = common.apply_norm(cfg.norm, layer["attn_norm"], h)
    if cfg.mla:
        out, _ = mla.mla_block(layer["attn"], cfg, x, chunked=chunked)
    else:
        out, _ = attn_mod.attention_block(
            layer["attn"], cfg, x, window=cfg.sliding_window, chunked=chunked
        )
    return out


def forward(
    params: Params,
    cfg: ArchConfig,
    tokens: Array,
    *,
    chunked_attn: bool = False,
    remat: bool = True,
    seq_shard: bool | None = None,
) -> tuple[Array, Array]:
    """Returns (hidden [B,S,d], aux_loss).

    ``seq_shard=None`` resolves ``$REPRO_SEQ_SHARD`` when this forward
    pass runs (or traces) — pass an explicit bool to pin it pre-trace.
    """
    if seq_shard is None:
        seq_shard = _seq_shard()
    h = common.embed(params["embed"], tokens)

    def dense_body(h, layer):
        h = h + _attn_fwd(layer, cfg, h, chunked_attn)
        m = common.mlp(
            layer["mlp"], "swiglu", common.apply_norm(cfg.norm, layer["mlp_norm"], h)
        )
        return h + m, None

    def moe_body(carry, layer):
        h, aux = carry
        h = h + _attn_fwd(layer, cfg, h, chunked_attn)
        if seq_shard:
            h = hints.hint(h, {0: ("pod", "data"), 1: "model"})
        y, aux_l = moe.moe_ffn(
            layer["moe"], cfg, common.apply_norm(cfg.norm, layer["mlp_norm"], h)
        )
        return (h + y, aux + aux_l), None

    maybe_ckpt = jax.checkpoint if remat else (lambda f: f)
    if "dense_layers" in params:
        h, _ = jax.lax.scan(maybe_ckpt(dense_body), h, params["dense_layers"])
    (h, aux), _ = jax.lax.scan(
        maybe_ckpt(moe_body), (h, jnp.zeros((), jnp.float32)), params["moe_layers"]
    )
    return common.apply_norm(cfg.norm, params["final_norm"], h), aux


def lm_loss(
    params: Params,
    cfg: ArchConfig,
    tokens: Array,
    *,
    chunked_attn: bool = False,
    loss_chunk: int = 1024,
) -> Array:
    h, aux = forward(params, cfg, tokens, chunked_attn=chunked_attn)
    h_in, labels = h[:, :-1], tokens[:, 1:]
    mask = jnp.ones_like(labels, jnp.float32)
    w = params["embed"]["table"] if cfg.tie_embeddings else params["lm_head"]
    xent = common.chunked_softmax_xent(
        h_in, labels, mask, w,
        chunk=min(loss_chunk, h_in.shape[1]),
        transpose=cfg.tie_embeddings,
    )
    return xent + cfg.router_aux_coef * aux


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def _init_layer_cache(cfg: ArchConfig, n_layers: int, batch: int, seq: int, dtype):
    if cfg.mla:
        s = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
        return mla.MLACache(
            c_kv=jnp.zeros((n_layers, batch, s, cfg.kv_lora_rank), dtype),
            k_pe=jnp.zeros((n_layers, batch, s, cfg.qk_rope_head_dim), dtype),
        )
    s = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
    shape = (n_layers, batch, s, cfg.n_kv_heads, cfg.head_dim)
    return attn_mod.KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def init_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype) -> MoECaches:
    n_dense = cfg.first_dense_layers
    dense = (
        _init_layer_cache(cfg, n_dense, batch, seq_len, dtype) if n_dense else None
    )
    return MoECaches(
        dense=dense,
        moe=_init_layer_cache(cfg, cfg.n_layers - n_dense, batch, seq_len, dtype),
    )


def _decode_attn(layer, cfg: ArchConfig, h, cache_slice, pos, slot):
    x = common.apply_norm(cfg.norm, layer["attn_norm"], h)
    if cfg.mla:
        out, new_c = mla.mla_block(
            layer["attn"], cfg, x,
            cache=mla.MLACache(*cache_slice), cache_pos=pos, write_slot=slot,
        )
        return out, tuple(new_c)
    out, new_c = attn_mod.attention_block(
        layer["attn"], cfg, x,
        cache=attn_mod.KVCache(*cache_slice), cache_pos=pos, write_slot=slot,
    )
    return out, tuple(new_c)


def decode_step(
    params: Params,
    cfg: ArchConfig,
    caches: MoECaches,
    token: Array,
    pos: Array,
) -> tuple[Array, MoECaches]:
    h = common.embed(params["embed"], token)
    cache_len = (
        caches.moe.c_kv.shape[2] if cfg.mla else caches.moe.k.shape[2]
    )
    slot = pos % cache_len if cfg.sliding_window else pos

    def dense_body(h, xs):
        layer, *cache_slice = xs
        a, new_c = _decode_attn(layer, cfg, h, cache_slice, pos, slot)
        h = h + a
        h = h + common.mlp(
            layer["mlp"], "swiglu", common.apply_norm(cfg.norm, layer["mlp_norm"], h)
        )
        return h, new_c

    def moe_body(h, xs):
        layer, *cache_slice = xs
        a, new_c = _decode_attn(layer, cfg, h, cache_slice, pos, slot)
        h = h + a
        y, _ = moe.moe_ffn(
            layer["moe"], cfg, common.apply_norm(cfg.norm, layer["mlp_norm"], h)
        )
        return h + y, new_c

    new_dense = caches.dense
    if "dense_layers" in params:
        h, new_dense = jax.lax.scan(
            dense_body, h, (params["dense_layers"], *caches.dense)
        )
        new_dense = type(caches.dense)(*new_dense)
    h, new_moe = jax.lax.scan(moe_body, h, (params["moe_layers"], *caches.moe))
    new_moe = type(caches.moe)(*new_moe)

    h = common.apply_norm(cfg.norm, params["final_norm"], h)
    w = params["embed"]["table"] if cfg.tie_embeddings else params["lm_head"]
    logits = common.logits_from_hidden(
        h, params["embed"], None if cfg.tie_embeddings else w
    )
    return logits, MoECaches(dense=new_dense, moe=new_moe)

"""Shared model components: norms, MLPs, embeddings, RoPE, losses, init.

All modules are functional: ``init_*`` returns a params dict, ``apply``-style
functions take (params, inputs).  Parameters are plain nested dicts so the
launcher can attach sharding rules by path-name matching.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Array = jnp.ndarray
Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None) -> Array:
    """Truncated-normal fan-in init (LLM standard)."""
    fan_in = shape[0]
    std = scale if scale is not None else fan_in**-0.5
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def embed_init(key, shape, dtype) -> Array:
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: Array, eps: float = 1e-6) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps)
    out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def init_norm(kind: str, d: int, dtype) -> Params:
    return init_rmsnorm(d, dtype) if kind == "rmsnorm" else init_layernorm(d, dtype)


def apply_norm(kind: str, p: Params, x: Array) -> Array:
    return rmsnorm(p, x) if kind == "rmsnorm" else layernorm(p, x)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, kind: str, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(k1, (d_model, d_ff), dtype),
            "w_up": dense_init(k2, (d_model, d_ff), dtype),
            "w_down": dense_init(k3, (d_ff, d_model), dtype),
        }
    return {  # gelu_mlp (whisper-style 2-matrix MLP with bias)
        "w_up": dense_init(k1, (d_model, d_ff), dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": dense_init(k2, (d_ff, d_model), dtype),
        "b_down": jnp.zeros((d_model,), dtype),
    }


def mlp(p: Params, kind: str, x: Array) -> Array:
    if kind == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    if kind == "geglu":
        return (jax.nn.gelu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return (jax.nn.gelu(x @ p["w_up"] + p["b_up"])) @ p["w_down"] + p["b_down"]


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> Array:
    """Inverse frequencies [head_dim//2] (float32)."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    head_dim = x.shape[-1]
    inv = rope_frequencies(head_dim, theta)
    angles = positions[..., :, None, None].astype(jnp.float32) * inv  # [..., S,1,hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> Array:
    """Whisper-style fixed sinusoidal embeddings [seq, d] (float32)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    inv = 1.0 / (10000 ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    angles = pos * inv[None, :]
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


# ---------------------------------------------------------------------------
# Embedding / LM head / loss
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype) -> Params:
    return {"table": embed_init(key, (vocab, d), dtype)}


def embed(p: Params, tokens: Array) -> Array:
    return p["table"][tokens]


def logits_from_hidden(h: Array, emb: Params, w_out: Array | None) -> Array:
    """LM head: tied embedding transpose or a separate output matrix."""
    if w_out is not None:
        return h @ w_out
    return h @ emb["table"].T


def chunked_softmax_xent(
    h: Array,
    labels: Array,
    mask: Array,
    emb_or_w: Array,
    *,
    chunk: int = 1024,
    transpose: bool = False,
) -> Array:
    """Cross-entropy over a large vocab without materializing [T, V] logits.

    h: [B, S, d]; labels/mask: [B, S]; emb_or_w: [V, d] (transpose=True) or
    [d, V].  Scans over sequence chunks: the peak live logits tensor is
    [B, chunk, V].  Returns mean NLL over masked positions (float32).
    """
    b, s, d = h.shape
    n_chunks = max(1, s // chunk)
    chunk = s // n_chunks
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"

    hs = h.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)          # [C,B,c,d]
    ls = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)        # [C,B,c]
    ms = mask.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    def body(carry, xs):
        total, count = carry
        hc, lc, mc = xs
        logits = (hc @ emb_or_w.T if transpose else hc @ emb_or_w).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mc
        return (total + nll.sum(), count + mc.sum()), None

    (total, count), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hs, ls, ms)
    )
    return total / jnp.maximum(count, 1.0)

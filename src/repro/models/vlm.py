"""InternVL2-style VLM (arXiv:2404.16821): stub ViT frontend + LLM decoder.

Per the assignment spec the InternViT vision encoder is a STUB —
``input_specs`` supplies precomputed patch embeddings [B, n_patches,
d_frontend].  This module owns the MLP projector (pixel-shuffle + 2-layer MLP
in the real model; here a 2-layer MLP, which is the trainable part) and wraps
the InternLM2 decoder (models/transformer.py) with the projected patch tokens
as a prefix.  Loss is computed on text positions only.

Decode reuses the dense decode path: image tokens are part of the prefill;
the KV cache covers prefix + text.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common, transformer

Array = jnp.ndarray
Params = dict[str, Any]


def init_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    k_lm, k_p1, k_p2 = jax.random.split(key, 3)
    params = transformer.init_params(k_lm, cfg, dtype)
    params["projector"] = {
        "norm": common.init_layernorm(cfg.d_frontend, dtype),
        "w1": common.dense_init(k_p1, (cfg.d_frontend, cfg.d_model), dtype),
        "b1": jnp.zeros((cfg.d_model,), dtype),
        "w2": common.dense_init(k_p2, (cfg.d_model, cfg.d_model), dtype),
        "b2": jnp.zeros((cfg.d_model,), dtype),
    }
    return params


def project(params: Params, patch_embeds: Array) -> Array:
    p = params["projector"]
    x = common.layernorm(p["norm"], patch_embeds)
    x = jax.nn.gelu(x @ p["w1"] + p["b1"])
    return x @ p["w2"] + p["b2"]


def lm_loss(
    params: Params,
    cfg: ArchConfig,
    patch_embeds: Array,
    tokens: Array,
    *,
    chunked_attn: bool = False,
) -> Array:
    prefix = project(params, patch_embeds)
    return transformer.lm_loss(
        params, cfg, tokens, prefix_embeds=prefix, chunked_attn=chunked_attn
    )


init_cache = transformer.init_cache
decode_step = transformer.decode_step

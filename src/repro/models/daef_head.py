"""DAEF head — the paper's technique attached to any backbone (DESIGN.md §4).

Wraps repro.core.daef around transformer hidden states: fit NON-ITERATIVELY
on pooled activations of in-distribution traffic, then score new sequences by
reconstruction error.  Works with every ModelBundle family (it only consumes
activation matrices), federates across data shards (a data-sharded
`repro.engine` mesh plan), and never ships raw activations between nodes — the deployment story of
examples/llm_feature_anomaly.py as a library component.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core import anomaly, daef

Array = jnp.ndarray


@dataclasses.dataclass
class DAEFHead:
    """A fitted DAEF anomaly head over backbone features."""

    cfg: daef.DAEFConfig
    model: daef.DAEFModel
    mean: Array       # feature standardization (fit on normal data)
    std: Array
    threshold: Array

    def score(self, feats: Array) -> Array:
        """feats [n, d] -> per-sample reconstruction error."""
        x = ((feats - self.mean) / self.std).T
        return daef.reconstruction_error(self.cfg, self.model, x)

    def flag(self, feats: Array) -> Array:
        """1 = anomalous (error above the fitted threshold)."""
        return anomaly.classify(self.score(feats), self.threshold)


def default_config(d_model: int, *, latent_frac: int = 8) -> daef.DAEFConfig:
    return daef.DAEFConfig(
        layer_sizes=(d_model, d_model // latent_frac, d_model // 4, d_model),
        lam_hidden=0.1,
        lam_last=0.5,
    )


def fit_head(
    feats: Array,
    *,
    cfg: daef.DAEFConfig | None = None,
    rule: str = "q90",
    n_partitions: int = 4,
    mesh=None,
    data_axes=("data",),
) -> DAEFHead:
    """Fit a DAEF head on normal-traffic features [n, d].

    With ``mesh`` given, the fit runs on-mesh (each data shard = one
    federated node); otherwise a host fit with ``n_partitions`` exercising
    the same merge path.
    """
    from repro.engine import DAEFEngine, ExecutionPlan

    feats = jnp.asarray(feats)
    mean = feats.mean(axis=0)
    std = feats.std(axis=0) + 1e-6
    x = ((feats - mean) / std).T  # [d, n] — the paper's convention
    if cfg is None:
        cfg = default_config(x.shape[0])
    if mesh is not None:
        engine = DAEFEngine(
            cfg, ExecutionPlan(mode="mesh", mesh_axes=tuple(data_axes)),
            mesh=mesh,
        )
        model = engine.fit(x)
    else:
        model = daef.fit(cfg, x, n_partitions=n_partitions)
    thr = anomaly.threshold(model.train_errors, rule)
    return DAEFHead(cfg=cfg, model=model, mean=mean, std=std, threshold=thr)


def pooled_features(
    forward: Callable[[Array], Array], tokens: Array
) -> Array:
    """Mean-pool a backbone's hidden states into [batch, d] features."""
    h = forward(tokens)
    return np.asarray(h.mean(axis=1))

"""Synthetic replicas of the paper's seven anomaly-detection datasets.

This container is offline, so the UCI/Kaggle data of Table 1 is not
available.  Each replica reproduces the *statistical shape* of its dataset —
size, dimension and anomaly rate — with normal samples living on a random
nonlinear low-rank manifold (rank ~ dim/3) plus noise, and anomalies drawn
off-manifold (scaled isotropic + manifold-orthogonal shifts).  This preserves
what DAEF exploits (a learnable low-dimensional normal class) so the paper's
*claims* (F1 parity with iterative AEs, training-speed ratio) remain
checkable; absolute F1 values are not comparable to the paper (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np

# name -> (n_total, anomalies, dim)  — paper Table 1
PAPER_DATASETS: dict[str, tuple[int, int, int]] = {
    "shuttle": (49097, 3511, 9),
    "covertype": (286048, 2747, 10),
    "pendigits": (6870, 156, 16),
    "cardio": (1831, 176, 21),
    "creditcard": (284807, 492, 29),
    "ionosphere": (351, 126, 33),
    "optdigit": (5216, 64, 62),
}


@dataclasses.dataclass
class AnomalyDataset:
    """Column-major (features x samples) like the paper."""

    name: str
    x_normal: np.ndarray    # [dim, n_normal]
    x_anomaly: np.ndarray   # [dim, n_anomaly]

    @property
    def dim(self) -> int:
        return self.x_normal.shape[0]

    def train_test_split(self, fold: int, n_folds: int = 10):
        """Paper protocol: train on normal only (k-fold over normals); test on
        held-out normals + an equal-sized anomaly sample (50/50)."""
        n = self.x_normal.shape[1]
        idx = np.arange(n)
        rng = np.random.default_rng(1234)
        rng.shuffle(idx)
        lo, hi = round(fold * n / n_folds), round((fold + 1) * n / n_folds)
        test_idx, train_idx = idx[lo:hi], np.concatenate([idx[:lo], idx[hi:]])
        x_train = self.x_normal[:, train_idx]
        x_test_norm = self.x_normal[:, test_idx]
        n_anom = min(self.x_anomaly.shape[1], x_test_norm.shape[1])
        a_idx = np.random.default_rng(fold).choice(
            self.x_anomaly.shape[1], size=n_anom, replace=False
        )
        x_test = np.concatenate([x_test_norm, self.x_anomaly[:, a_idx]], axis=1)
        y_test = np.concatenate(
            [np.zeros(x_test_norm.shape[1]), np.ones(n_anom)]
        ).astype(np.int32)
        return x_train, x_test, y_test


def make_dataset(name: str, seed: int = 0, scale: float = 1.0) -> AnomalyDataset:
    """Generate the synthetic replica of a paper dataset.

    ``scale`` < 1 shrinks the sample count (for fast tests) while keeping
    dim and anomaly rate.
    """
    n_total, n_anom, dim = PAPER_DATASETS[name]
    rate = n_anom / n_total
    n_total = max(64, int(n_total * scale))
    # Preserve the paper's anomaly rate under scaling.
    n_anom = max(4, round(n_total * rate))
    n_norm = n_total - n_anom
    # zlib.crc32, not hash(): Python string hashing is randomized per
    # process and would make "deterministic" datasets differ across runs.
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % (2**31))

    rank = max(2, dim // 3)
    mix = rng.normal(size=(dim, rank)) / np.sqrt(rank)
    bend = rng.normal(size=(dim, rank)) / np.sqrt(rank)

    def sample_normal(n):
        z = rng.normal(size=(rank, n))
        x = mix @ z + 0.6 * np.tanh(bend @ (z * z - 1.0))
        return x + 0.08 * rng.normal(size=(dim, n))

    x_norm = sample_normal(n_norm)

    # Anomalies: a blend of (a) isotropic far-field noise and (b) on-manifold
    # points pushed along directions orthogonal to the manifold.
    n_a1 = n_anom // 2
    a1 = 2.2 * rng.normal(size=(dim, n_a1))
    base = sample_normal(n_anom - n_a1)
    q, _ = np.linalg.qr(rng.normal(size=(dim, dim)))
    ortho = q[:, rank:]
    push = ortho @ rng.normal(size=(ortho.shape[1], n_anom - n_a1))
    a2 = base + 1.8 * push / np.maximum(np.linalg.norm(push, axis=0, keepdims=True), 1e-9)
    x_anom = np.concatenate([a1, a2], axis=1)

    # Standard-scale using the normal-class statistics (paper: zero mean /
    # unit variance scalers).
    mean = x_norm.mean(axis=1, keepdims=True)
    std = x_norm.std(axis=1, keepdims=True) + 1e-9
    return AnomalyDataset(
        name=name,
        x_normal=((x_norm - mean) / std).astype(np.float32),
        x_anomaly=((x_anom - mean) / std).astype(np.float32),
    )


def lm_token_stream(
    vocab_size: int, seq_len: int, batch: int, seed: int = 0
) -> np.ndarray:
    """Synthetic token batches for LM training/serving smoke tests.

    A Zipfian unigram model with short-range repetition structure — enough
    signal for a loss to go down without any external corpus.
    """
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = rng.choice(vocab_size, size=(batch, seq_len), p=probs)
    # Inject copy structure: with p=0.3 repeat the token 8 positions back.
    if seq_len > 8:
        mask = rng.random((batch, seq_len - 8)) < 0.3
        toks[:, 8:][mask] = toks[:, :-8][mask]
    return toks.astype(np.int32)

"""Data substrate: synthetic dataset replicas + batching/sharding pipeline."""
from repro.data.synthetic import (  # noqa: F401
    PAPER_DATASETS,
    AnomalyDataset,
    lm_token_stream,
    make_dataset,
)
from repro.data.pipeline import batches, shard_batch, token_batches  # noqa: F401

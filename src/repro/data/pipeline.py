"""Host-side data pipeline: batching, sharding, prefetch-style iteration.

Deliberately simple and dependency-free: deterministic numpy batching with
per-epoch shuffling, plus a helper that device_puts global batches with the
mesh sharding the launcher requests.
"""
from __future__ import annotations

from typing import Callable, Iterator

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def batches(
    x: np.ndarray,
    batch_size: int,
    *,
    axis: int = 1,
    seed: int = 0,
    epochs: int | None = None,
    drop_remainder: bool = True,
) -> Iterator[np.ndarray]:
    """Shuffled mini-batches along ``axis`` (column-major like the core)."""
    n = x.shape[axis]
    rng = np.random.default_rng(seed)
    epoch = 0
    while epochs is None or epoch < epochs:
        idx = rng.permutation(n)
        stop = (n // batch_size) * batch_size if drop_remainder else n
        for lo in range(0, stop, batch_size):
            take = idx[lo : lo + batch_size]
            yield np.take(x, take, axis=axis)
        epoch += 1


def token_batches(
    sampler: Callable[[int], np.ndarray],
    steps: int,
) -> Iterator[np.ndarray]:
    """LM batches from a seeded sampler(step) -> [batch, seq] int32."""
    for step in range(steps):
        yield sampler(step)


def shard_batch(batch, mesh: Mesh, spec: P):
    """Place a host batch onto the mesh with the given PartitionSpec."""
    sharding = NamedSharding(mesh, spec)
    return jax.tree.map(lambda a: jax.device_put(a, sharding), batch)

"""Version-tolerant shims over jax APIs that moved between releases.

The repo targets the mesh-context API (``jax.set_mesh`` /
``jax.sharding.get_abstract_mesh``) introduced after 0.4.x; the baked
toolchain ships jax 0.4.37 where the equivalent state lives in
``Mesh.__enter__`` / ``thread_resources``.  Everything that touches the
ambient mesh goes through this module so the rest of the codebase can be
written against one API.
"""
from __future__ import annotations

import contextlib

import jax


def get_abstract_mesh():
    """The ambient mesh, or None when no mesh context is active.

    Newer jax tracks an *abstract* mesh; on 0.4.x we fall back to the
    physical mesh installed by ``with mesh:`` (thread resources), which is
    what ``with_sharding_constraint`` consults there anyway.
    """
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        return getter()
    from jax._src import mesh as mesh_lib

    phys = mesh_lib.thread_resources.env.physical_mesh
    if phys is None or phys.empty:
        return None
    return phys


def set_mesh(mesh):
    """Context manager activating ``mesh`` — ``jax.set_mesh`` when available,
    otherwise the classic ``with mesh:`` entry (jax 0.4.x)."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        ctx = setter(mesh)
        # Some versions expose set_mesh as a plain global setter returning
        # None rather than a context manager; fall through to `with mesh:`
        # (which shadows, and on exit restores, whatever the setter did).
        if hasattr(ctx, "__enter__"):
            return ctx
    return _enter_mesh(mesh)


@contextlib.contextmanager
def _enter_mesh(mesh):
    with mesh:
        yield mesh


def axis_size(axis_name):
    """Size of a mapped mesh axis inside shard_map — ``lax.axis_size`` on
    newer jax, the psum-of-ones identity on 0.4.x."""
    getter = getattr(jax.lax, "axis_size", None)
    if getter is not None:
        return getter(axis_name)
    return jax.lax.psum(1, axis_name)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with explicit Auto axis types when the running jax
    supports them (0.4.x has neither ``AxisType`` nor the kwarg)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                axis_shapes, axis_names,
                axis_types=(axis_type.Auto,) * len(axis_names),
            )
        except TypeError:
            pass
    return jax.make_mesh(axis_shapes, axis_names)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check_vma=True):
    """``jax.shard_map`` (new API: ``axis_names``/``check_vma``) with a
    fallback to ``jax.experimental.shard_map`` (0.4.x: ``auto``/``check_rep``).

    ``axis_names`` is the set of *manual* axes; on the old API every other
    mesh axis goes into ``auto``.
    """
    manual = frozenset(axis_names) if axis_names else frozenset(mesh.axis_names)
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  axis_names=manual, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as sm_old

    auto = frozenset(mesh.axis_names) - manual
    # check_rep is the old name for check_vma, but its implementation lacks
    # replication rules for several primitives this repo uses inside
    # shard_map (eigh/svd raise NotImplementedError) — disable it; the check
    # still runs wherever the new API is available.  The old eager impl also
    # rejects non-empty ``auto``, so the mapped fn must run under jit.
    mapped = sm_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    check_rep=False, auto=auto)
    return jax.jit(mapped) if auto else mapped
